"""Controllers as data: pure per-round decision functions + traced dispatch.

The stateful controller classes (``LROAController``, ``UniformDynamic...``,
``UniformStatic...``, ``DivFLController``) exist for the host-driven
Algorithm-1 loop, but the fused rollout paths — ``RoundEngine.run_scan``
and the ScenarioArena's scenario-batched sweeps (``repro.sim``) — need the
*decision rule itself* to be a pure, jit/vmap-composable function of
``(params, h, queues, V, lam)``.  This module is the single home of those
rules — the controller zoo:

* :func:`decide_lroa`          — Algorithm 2 (``solver.solve_p2``);
* :func:`decide_uni_d`         — uniform q, LROA's dynamic (f, p) forms;
* :func:`decide_uni_s`         — uniform q, mid-range p, f from the Uni-S
  energy-balance equation (:func:`static_frequency`);
* :func:`decide_channel_aware` — Shi-style best-channel scheduling
  (arXiv:1911.00856): all selection mass on the K strongest channels,
  dynamic (f, p) under that q;
* :func:`decide_cost_effective`— Luo-style adaptive sampling
  (arXiv:2109.05411): q proportional to data weight per unit round cost,
  static resources;
* :func:`decide_round_robin`   — uniform resources, deterministic cyclic
  selection (the selection layer below);
* :func:`decide_divfl`         — DivFL's resource plan (uniform q, static
  resources); its *selection* is the in-trace facility-location greedy.

``POLICIES`` fixes the id order and :func:`decide_by_id` dispatches on a
*traced* integer via ``lax.switch`` — the controller becomes per-scenario
data, so a single jitted program can run a mixed-controller grid (each
scenario lane selects its own branch; under ``vmap`` every branch runs on
the full batch and the select keeps each lane bit-identical to the pure
branch).  The stateful classes are thin wrappers over these functions, so
the host loop and the fused paths cannot diverge.

Selection layer
---------------
A decision rule emits the *distribution* (f, p, q); HOW the K client
slots are filled from it is a second, per-controller axis.  Three modes,
registered per policy in :data:`SELECTION_MODES` and dispatched on the
traced id by :func:`select_by_id`:

* ``sampled`` (:func:`sampled_selection`) — the paper's i.i.d.
  with-replacement draw: slot ``i`` samples from ``q`` under
  ``fold_in(round_key, i)`` (prefix-stable in the slot index, the
  padded-K invariant);
* ``round_robin`` (:func:`round_robin_selection`) — deterministic cyclic
  schedule ``(t * K + slot) mod N``; every client is visited equally
  often regardless of channel state;
* ``greedy`` (:func:`divfl_selection`) — DivFL as a K-step
  ``lax.fori_loop`` of masked facility-location argmax over the
  normalized client-feature gram matrix (:func:`divfl_similarity`).  The
  loop is prefix-stable: step ``i`` depends only on steps ``< i``, so a
  padded rollout picks the identical first ``k_act`` clients, and the
  host ``core.baselines.facility_location_greedy`` run on the same
  similarity reproduces the trace's picks exactly (the equivalence
  pinned by ``tests/test_divfl_trace.py``).

Deterministic modes ignore the slot PRNG keys; the sampled mode ignores
the round index — the shared signature is what lets ``lax.switch`` mix
them in one executable.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import solver as slv
from repro.core import system_model as sm

Array = jax.Array

#: Scan-traceable policies, in controller-id order (the ``lax.switch``
#: branch index).  The names are the public contract — ``run_scan``'s
#: ``policy=`` strings and the ScenarioArena's grid both resolve through
#: ``POLICY_IDS``.  Ids 0-2 predate the zoo and are frozen.
POLICIES = ("lroa", "uni_d", "uni_s", "channel_aware", "cost_effective",
            "round_robin", "divfl")
POLICY_IDS = {name: i for i, name in enumerate(POLICIES)}


def _uniform_q(n: int) -> Array:
    return jnp.full((n,), 1.0 / n, jnp.float32)


def _mid_power(params: sm.SystemParams) -> Array:
    return jnp.broadcast_to(0.5 * (params.p_min + params.p_max),
                            (params.num_devices,))


def decide_lroa(params: sm.SystemParams, h: Array, queues: Array,
                V: Array, lam: Array,
                cfg: slv.SolverConfig = slv.SolverConfig(),
                k: Array = None) -> slv.ControlDecision:
    """LROA: the full Algorithm-2 drift-plus-penalty solve.

    ``k`` (every rule accepts it) optionally replaces the static
    ``params.sample_count`` with a traced per-rollout K — the padded-K
    rollout paths sweep K per scenario lane, so the decision math must
    read it from data, not from the executable.  ``None`` keeps the
    static host-controller path byte-identical to before.
    """
    return slv.solve_p2(params, h, queues, V, lam, cfg, k=k)


def decide_uni_d(params: sm.SystemParams, h: Array, queues: Array,
                 V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Uni-D: q = 1/N; (f, p) from the Theorem-2/3 closed forms."""
    q = _uniform_q(params.num_devices)
    f = slv.solve_f(params, q, queues, V, k=k)
    p = slv.solve_p(params, q, queues, h, V, cfg.bisect_iters, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


def static_frequency(params: sm.SystemParams, h: Array, p: Array,
                     k: Array = None) -> Array:
    """Solve the Uni-S energy-balance for f (projected to [f_min, f_max]).

    [E alpha c D f^2 / 2 + p M K / (B log2(1 + h p / N0))] * sel = Ebar
    with sel = 1 - (1 - 1/N)^K  =>  f^2 = 2 (Ebar/sel - E_com) / (E alpha c D).
    """
    n = params.num_devices
    sel = 1.0 - (1.0 - 1.0 / n) ** sm.effective_k(params, k)
    e_com = sm.comm_energy(params, h, p, k=k)
    cycles = params.local_epochs * params.capacitance * \
        params.cycles_per_sample * params.data_sizes
    f_sq = 2.0 * (params.energy_budget / sel - e_com) / jnp.maximum(cycles,
                                                                    1e-30)
    f = jnp.sqrt(jnp.maximum(f_sq, 0.0))
    return jnp.clip(f, params.f_min, params.f_max)


def decide_uni_s(params: sm.SystemParams, h: Array, queues: Array,
                 V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Uni-S: q = 1/N, p mid-range, f from the energy-balance equation.

    ``queues`` / ``V`` / ``lam`` are accepted (and ignored) so every
    policy shares one signature — the requirement for ``lax.switch``
    dispatch and for the scenario grid to carry (V, lam) uniformly.
    """
    q = _uniform_q(params.num_devices)
    p = _mid_power(params)
    f = static_frequency(params, h, p, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


def decide_channel_aware(params: sm.SystemParams, h: Array, queues: Array,
                         V: Array, lam: Array,
                         cfg: slv.SolverConfig = slv.SolverConfig(),
                         k: Array = None) -> slv.ControlDecision:
    """Best-channel scheduling (Shi et al., arXiv:1911.00856).

    All selection mass goes — uniformly — to the K devices with the
    strongest current channel gains (``rank(h) < K``), the fast-convergence
    scheduling rule; (f, p) then follow LROA's Theorem-2/3 closed forms
    under that q (zero-q devices fall into the closed forms' no-pressure
    branch and clip to the box, but carry no selection mass).  Myopic in
    the channel: it never looks at queues, which is exactly the contrast
    the Sec.-VII comparison is after.
    """
    k_eff = sm.effective_k(params, k)
    # rank 0 = strongest channel; double-argsort is the jit-stable rank
    ranks = jnp.argsort(jnp.argsort(-h))
    mask = (ranks < k_eff).astype(jnp.float32)
    q = mask / jnp.sum(mask)
    f = slv.solve_f(params, q, queues, V, k=k)
    p = slv.solve_p(params, q, queues, h, V, cfg.bisect_iters, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


def decide_cost_effective(params: sm.SystemParams, h: Array, queues: Array,
                          V: Array, lam: Array,
                          cfg: slv.SolverConfig = slv.SolverConfig(),
                          k: Array = None) -> slv.ControlDecision:
    """Adaptive cost-effective sampling (Luo et al., arXiv:2109.05411).

    Samples clients with probability proportional to statistical utility
    per sqrt round cost: ``q_n ∝ w_n / sqrt(T_n)`` with ``T_n`` the
    client's full round time under static resources — the "more data,
    cheaper round" trade Luo's adaptive sampling optimises.  A
    ``cfg.q_floor`` floor keeps every q strictly positive (the unbiased
    eq.-(4) aggregation divides by q of the picked client).
    """
    p = _mid_power(params)
    f = static_frequency(params, h, p, k=k)
    cost = sm.round_time(params, h, p, f, k=k)
    score = params.data_weights / jnp.sqrt(jnp.maximum(cost, 1e-12))
    q = score / jnp.sum(score)
    q = jnp.maximum(q, cfg.q_floor)
    q = q / jnp.sum(q)
    return slv.ControlDecision(f=f, p=p, q=q)


def decide_round_robin(params: sm.SystemParams, h: Array, queues: Array,
                       V: Array, lam: Array,
                       cfg: slv.SolverConfig = slv.SolverConfig(),
                       k: Array = None) -> slv.ControlDecision:
    """Round-robin: uniform q with a deterministic cyclic *selection*.

    The reported q is the long-run visit frequency 1/N (what the unbiased
    eq.-(4) coefficients and the expected-energy queue drift consume);
    the actual slot fill is the cyclic schedule in
    :func:`round_robin_selection`.  Resources follow Uni-D's dynamic
    closed forms so the contrast with ``uni_d`` isolates the selection
    discipline.
    """
    q = _uniform_q(params.num_devices)
    f = slv.solve_f(params, q, queues, V, k=k)
    p = slv.solve_p(params, q, queues, h, V, cfg.bisect_iters, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


def decide_divfl(params: sm.SystemParams, h: Array, queues: Array,
                 V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """DivFL resource plan: uniform q, mid-range p, energy-balance f.

    Mirrors ``core.baselines.DivFLController.decide`` — DivFL prescribes
    no resource allocation, so it reuses Uni-S's static plan; what makes
    it DivFL is the greedy facility-location *selection*
    (:func:`divfl_selection`), dispatched by :data:`SELECTION_MODES`.
    """
    q = _uniform_q(params.num_devices)
    p = _mid_power(params)
    f = static_frequency(params, h, p, k=k)
    return slv.ControlDecision(f=f, p=p, q=q)


#: Branches in POLICY id order — ``DECIDE_FNS[POLICY_IDS[name]]`` is the
#: pure rule behind controller ``name``.
DECIDE_FNS = (decide_lroa, decide_uni_d, decide_uni_s,
              decide_channel_aware, decide_cost_effective,
              decide_round_robin, decide_divfl)


def decide_by_id(controller_id: Array, params: sm.SystemParams, h: Array,
                 queues: Array, V: Array, lam: Array,
                 cfg: slv.SolverConfig = slv.SolverConfig(),
                 k: Array = None) -> slv.ControlDecision:
    """Dispatch on a *traced* controller id (``lax.switch``).

    The id indexes :data:`POLICIES`; out-of-range ids clamp (lax.switch
    semantics).  Under ``vmap`` with a batched id every branch executes on
    the full batch and each lane selects its own — which is exactly what
    lets the ScenarioArena run a mixed-controller grid in ONE jitted
    program while staying bit-identical per lane to the fixed-policy
    rollout.  ``k`` (optional traced per-rollout K) is forwarded to every
    branch — the padded-K arena path, where K is per-scenario data.
    """
    if k is None:
        branches = [partial(fn, cfg=cfg) for fn in DECIDE_FNS]
        return jax.lax.switch(controller_id, branches, params, h, queues,
                              V, lam)
    branches = [
        (lambda p, hh, qq, vv, ll, kk, fn=fn: fn(p, hh, qq, vv, ll,
                                                 cfg=cfg, k=kk))
        for fn in DECIDE_FNS]
    return jax.lax.switch(controller_id, branches, params, h, queues, V,
                          lam, k)


# --------------------------------------------------------------------------
# Selection layer — how the K slots are filled from a ControlDecision
# --------------------------------------------------------------------------

#: Selection-mode indices (the ``lax.switch`` branch order of
#: :data:`SELECT_FNS`).
SELECT_SAMPLED, SELECT_ROUND_ROBIN, SELECT_GREEDY = 0, 1, 2

#: Per-policy selection mode, aligned with :data:`POLICIES`.
SELECTION_MODES = {
    "lroa": SELECT_SAMPLED,
    "uni_d": SELECT_SAMPLED,
    "uni_s": SELECT_SAMPLED,
    "channel_aware": SELECT_SAMPLED,
    "cost_effective": SELECT_SAMPLED,
    "round_robin": SELECT_ROUND_ROBIN,
    "divfl": SELECT_GREEDY,
}
_MODE_TABLE = tuple(SELECTION_MODES[name] for name in POLICIES)


def sampled_selection(params: sm.SystemParams, t: Array, h: Array,
                      queues: Array, q: Array, key: Array, slots: Array,
                      kvec: Array) -> Array:
    """The paper's i.i.d. with-replacement draw from q, one key per slot.

    Prefix-stable: slot ``i`` draws from ``fold_in(key, i)`` only — never
    from ``K_max`` — the padded-K invariant ``_build_scan`` documents.
    This is byte-for-byte the selection the pre-zoo scan body inlined.
    """
    n = params.num_devices
    sel_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(slots)
    return jax.vmap(
        lambda sk: jax.random.choice(sk, n, (), replace=True,
                                     p=q))(sel_keys)


def round_robin_selection(params: sm.SystemParams, t: Array, h: Array,
                          queues: Array, q: Array, key: Array,
                          slots: Array, kvec: Array) -> Array:
    """Deterministic cyclic schedule: round t fills slot i with client
    ``(t * K + i) mod N``.

    Consecutive rounds continue the cycle (K distinct clients per round
    whenever K <= N), every client is visited once per ceil(N/K) rounds,
    and the schedule is prefix-stable in the slot index (slot i never
    reads K_max), so padded lanes truncate to the same prefix.
    """
    n = params.num_devices
    k_i = jnp.reshape(kvec, (-1,))[0].astype(slots.dtype)
    return (t.astype(slots.dtype) * k_i + slots) % n


def divfl_features(params: sm.SystemParams, h: Array) -> Array:
    """Per-client control-plane feature sketch ``[N, 2]`` for DivFL.

    DivFL proper builds its similarity from observed gradient sketches;
    inside the fused scan the control plane must stay a pure function of
    the round inputs (selections feed the dispatch-footprint probe and
    the host replay, both of which run WITHOUT training), so the sketch
    is the per-client ``(data weight, channel gain)`` pair — the same
    observable state every other rule conditions on.  The greedy itself
    (:func:`facility_location_select`) is sketch-agnostic; tests feed it
    real gradient-sketch grams.
    """
    return jnp.stack([params.data_weights, h], axis=1)


def divfl_similarity(feats: Array) -> Array:
    """Row-normalized gram matrix ``[N, N]`` of a ``[N, D]`` sketch."""
    norms = jnp.linalg.norm(feats, axis=1, keepdims=True)
    unit = feats / jnp.maximum(norms, 1e-12)
    return unit @ unit.T


def facility_location_select(similarity: Array, k: int) -> Array:
    """K-step greedy facility-location maximisation, in-trace.

    Step ``i`` scores every client by the coverage gain
    ``sum_n max(best_n, sim[n, j])`` with already-chosen clients masked
    to -inf, takes the argmax, and folds its column into ``best`` — the
    exact loop ``core.baselines.facility_location_greedy`` runs on the
    host (argmax ties break low-index in both).  ``k`` is the STATIC
    slot count; the loop is prefix-stable (step i reads only steps < i),
    so a padded rollout's first ``k_act`` picks equal the unpadded run's.
    """
    n = similarity.shape[0]

    def step(i, carry):
        best, chosen, out = carry
        gains = jnp.sum(jnp.maximum(best[:, None], similarity), axis=0)
        gains = jnp.where(chosen, -jnp.inf, gains)
        j = jnp.argmax(gains).astype(out.dtype)
        best = jnp.maximum(best, similarity[:, j])
        chosen = chosen.at[j].set(True)
        out = out.at[i].set(j)
        return best, chosen, out

    best0 = jnp.full((n,), -jnp.inf, similarity.dtype)
    chosen0 = jnp.zeros((n,), bool)
    out0 = jnp.zeros((k,), jnp.int32)
    _, _, out = jax.lax.fori_loop(0, k, step, (best0, chosen0, out0))
    return out


def divfl_selection(params: sm.SystemParams, t: Array, h: Array,
                    queues: Array, q: Array, key: Array, slots: Array,
                    kvec: Array) -> Array:
    """DivFL: greedy facility-location picks over the feature gram."""
    sim = divfl_similarity(divfl_features(params, h))
    return facility_location_select(sim, slots.shape[0])


#: Branches in SELECT_* mode order.
SELECT_FNS = (sampled_selection, round_robin_selection, divfl_selection)


def select_by_id(controller_id: Array, params: sm.SystemParams, t: Array,
                 h: Array, queues: Array, q: Array, key: Array,
                 slots: Array, kvec: Array) -> Array:
    """Traced selection dispatch: controller id -> selection mode.

    The static :data:`_MODE_TABLE` maps policy ids to the three selection
    modes; ``lax.switch`` then runs the mode branches.  Same vmap
    semantics as :func:`decide_by_id`: all three modes execute per lane
    and the select keeps each lane bitwise-equal to its pure branch —
    sampled lanes keep the exact pre-zoo draws.
    """
    mode = jnp.take(jnp.asarray(_MODE_TABLE, jnp.int32), controller_id)
    return jax.lax.switch(mode, list(SELECT_FNS), params, t, h, queues,
                          q, key, slots, kvec)
