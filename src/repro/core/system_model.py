"""Edge-device system model for FL over mobile edge networks.

Implements the communication/computation time and energy model of the paper
(Sections III-C .. III-F, eqs. (5)-(17)) as vectorised, jit-able JAX
functions over the device dimension ``[N]``.

Conventions
-----------
* All per-device quantities are 1-D arrays of shape ``[N]`` (float32).
* ``M`` is the model-update size in **bits** (the paper uses M = 32 d bits).
* Rates are bits/second; times are seconds; energies are Joules.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@partial(jax.tree_util.register_dataclass,
         data_fields=("cycles_per_sample", "data_sizes", "capacitance",
                      "energy_budget", "f_min", "f_max", "p_min", "p_max"),
         meta_fields=("num_devices", "sample_count", "local_epochs",
                      "bandwidth_hz", "noise_power", "model_bits",
                      "download_rate"))
@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static parameters of the FL edge system (paper Table I).

    Per-device arrays are pytree leaves of shape ``[N]``; scalars are
    static metadata, so a ``SystemParams`` passes directly through ``jit``.
    """

    num_devices: int                 # N
    sample_count: int                # K — sampling frequency (draws/round)
    local_epochs: int                # E
    bandwidth_hz: float              # B — total uplink bandwidth (Hz)
    noise_power: float               # N0 — background noise power (W)
    model_bits: float                # M — model update size in bits
    download_rate: float             # r_{n,d} — downlink rate (bits/s)
    # Heterogeneous per-device arrays (shape [N]):
    cycles_per_sample: Array         # c_n
    data_sizes: Array                # D_n (samples)
    capacitance: Array               # alpha_n
    energy_budget: Array             # \bar{E}_n (J / round, time-averaged)
    f_min: Array
    f_max: Array
    p_min: Array
    p_max: Array

    def __post_init__(self):
        for name in ("cycles_per_sample", "data_sizes", "capacitance",
                     "energy_budget", "f_min", "f_max", "p_min", "p_max"):
            arr = getattr(self, name)
            shape = getattr(arr, "shape", None)
            if shape is not None and tuple(shape) != (self.num_devices,):
                raise ValueError(
                    f"SystemParams.{name} must have shape ({self.num_devices},),"
                    f" got {shape}")

    @property
    def data_weights(self) -> Array:
        """w_n = D_n / D (paper Sec. III-A)."""
        d = jnp.asarray(self.data_sizes, jnp.float32)
        return d / jnp.sum(d)

    @property
    def per_device_bandwidth(self) -> float:
        """B_n = B / K under FDMA with even allocation (Sec. III-C)."""
        return self.bandwidth_hz / float(self.sample_count)

    def tree_arrays(self):
        return dict(
            cycles_per_sample=self.cycles_per_sample,
            data_sizes=self.data_sizes,
            capacitance=self.capacitance,
            energy_budget=self.energy_budget,
            f_min=self.f_min, f_max=self.f_max,
            p_min=self.p_min, p_max=self.p_max,
        )


def paper_default_params(num_devices: int = 120,
                         sample_count: int = 2,
                         local_epochs: int = 2,
                         model_params: int = 11_172_342,
                         dataset: str = "cifar10",
                         data_sizes: Optional[np.ndarray] = None,
                         param_bits: int = 32) -> SystemParams:
    """The paper's default experiment configuration (Sec. VII-A).

    p in [1e-3, 0.1] W, N0 = 0.01 W, f in [1.0, 2.0] GHz,
    alpha = 2e-28, B = 1 MHz, M = 32 * d bits,
    c = 3e9 (CIFAR-10) / 2e9 (FEMNIST) cycles/sample,
    E_bar = 15 J (CIFAR-10) / 5 J (FEMNIST).
    """
    n = num_devices
    if dataset == "cifar10":
        cycles, budget = 3.0e9, 15.0
    elif dataset == "femnist":
        cycles, budget = 2.0e9, 5.0
    else:
        raise ValueError(f"unknown dataset {dataset!r}")
    if data_sizes is None:
        data_sizes = np.full((n,), 50_000 // n, np.float32)
    ones = np.ones((n,), np.float32)
    return SystemParams(
        num_devices=n,
        sample_count=sample_count,
        local_epochs=local_epochs,
        bandwidth_hz=1.0e6,
        noise_power=0.01,
        model_bits=float(param_bits) * float(model_params),
        download_rate=1.0e7,  # downloads ignored in paper experiments; kept finite
        cycles_per_sample=cycles * ones,
        data_sizes=np.asarray(data_sizes, np.float32),
        capacitance=2.0e-28 * ones,
        energy_budget=budget * ones,
        f_min=1.0e9 * ones,
        f_max=2.0e9 * ones,
        p_min=1.0e-3 * ones,
        p_max=0.1 * ones,
    )


# --------------------------------------------------------------------------
# Time model (eqs. (5)-(11))
# --------------------------------------------------------------------------

def effective_k(params: SystemParams, k) -> Any:
    """The K a computation should read: the traced per-rollout override
    when given (scalar or ``[N]`` array — the padded-K rollout paths
    sweep K per scenario lane), else the static ``params.sample_count``.
    THE fallback idiom for every K-parameterised function below and in
    ``core.solver`` / ``core.policy``."""
    return params.sample_count if k is None else k


def uplink_rate(params: SystemParams, h: Array, p: Array,
                k: Optional[Array] = None) -> Array:
    """r_{n,u}^t = B_n log2(1 + h p / N0) — eq. (5).

    With a traced ``k``, B_n = B / K is computed in-trace; when ``k`` is
    None the static host path divides by the python int (the same value
    ``per_device_bandwidth`` precomputes).
    """
    bn = params.bandwidth_hz / effective_k(params, k)
    return bn * jnp.log2(1.0 + h * p / params.noise_power)


def upload_time(params: SystemParams, h: Array, p: Array,
                k: Optional[Array] = None) -> Array:
    """T_{n,u}^{t,com} = M / r_{n,u}^t — eq. (6)."""
    return params.model_bits / uplink_rate(params, h, p, k)


def download_time(params: SystemParams) -> Array:
    """T_{n,d}^{t,com} = M / r_{n,d} — eq. (7)."""
    return jnp.full((params.num_devices,),
                    params.model_bits / params.download_rate, jnp.float32)


def compute_time(params: SystemParams, f: Array) -> Array:
    """T_n^{t,cmp} = E c_n D_n / f — eq. (8)."""
    cycles = params.local_epochs * params.cycles_per_sample * params.data_sizes
    return cycles / f


def round_time(params: SystemParams, h: Array, p: Array, f: Array,
               include_download: bool = False,
               k: Optional[Array] = None) -> Array:
    """T_n^t — eq. (9). The paper's experiments ignore the download term."""
    t = compute_time(params, f) + upload_time(params, h, p, k)
    if include_download:
        t = t + download_time(params)
    return t


def expected_round_latency(q: Array, t_round: Array) -> Array:
    """max_n T_n ~= sum_n q_n T_n — the paper's surrogate, eq. (11)."""
    return jnp.sum(q * t_round)


# --------------------------------------------------------------------------
# Energy model (eqs. (12)-(17))
# --------------------------------------------------------------------------

def compute_energy(params: SystemParams, f: Array) -> Array:
    """E_n^{t,cmp} = E alpha_n c_n D_n f^2 / 2 — eq. (12)."""
    cycles = params.local_epochs * params.cycles_per_sample * params.data_sizes
    return 0.5 * params.capacitance * cycles * jnp.square(f)


def comm_energy(params: SystemParams, h: Array, p: Array,
                k: Optional[Array] = None) -> Array:
    """E_n^{t,com} = p * T_{n,u}^{t,com} — eq. (14)."""
    return p * upload_time(params, h, p, k)


def round_energy(params: SystemParams, h: Array, p: Array, f: Array,
                 k: Optional[Array] = None) -> Array:
    """E_n^t — eq. (15)."""
    return compute_energy(params, f) + comm_energy(params, h, p, k)


def selection_probability(q: Array, sample_count) -> Array:
    """1 - (1 - q)^K — probability device selected at least once (Sec. III-F).

    ``sample_count`` may be the static python int (host controllers) or a
    traced scalar / ``[N]`` array (the padded-K rollout paths, where K is
    per-scenario data).
    """
    return 1.0 - jnp.power(1.0 - q, sample_count)


def expected_energy(params: SystemParams, h: Array, p: Array, f: Array,
                    q: Array, k: Optional[Array] = None) -> Array:
    """Per-round expected energy draw entering constraint (16)."""
    return (selection_probability(q, effective_k(params, k)) *
            round_energy(params, h, p, f, k))
