"""Algorithm 2: efficient solution to the per-round drift-plus-penalty
problem P2 by alternating minimisation.

 * ``solve_f``  — Theorem 2 closed form (cube root, clipped).
 * ``solve_p``  — Theorem 3: root of ``(1+x)ln(1+x) - x = A_1`` with
   ``x = h p / N0``; the LHS is monotone increasing so a vectorised
   bisection converges geometrically.
 * ``solve_q``  — P2.2 via Successive Upper-bound Minimisation (SUM): the
   concave part is linearised at the current iterate and the resulting
   separable convex program over the probability simplex is solved EXACTLY
   by dual water-filling (bisection on the simplex multiplier).  This
   replaces the paper's call to CVX with a closed-form, jit-able routine.
 * ``solve_p2`` — the outer alternating loop of Algorithm 2.

All functions are pure and vectorised over the device axis ``[N]``; the whole
solver jits (fixed-trip-count bisections + ``lax.while_loop`` outer loop).

Note on P2.2's concave term: the paper prints ``- sum_n E_n (1-q_n)^K`` but
the drift derivation (Q_n * a_n with the q-independent parts dropped) gives
``- sum_n Q_n E_n (1-q_n)^K``; we implement the latter (the paper's line is a
typo — with Q_n == 0 the energy term must vanish, which only the derived form
satisfies).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import system_model as sm

Array = jax.Array

_EPS = 1e-12


class ControlDecision(NamedTuple):
    """Per-round control action (f^t, p^t, q^t), each shape [N]."""
    f: Array
    p: Array
    q: Array


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    outer_iters: int = 24          # Algorithm 2 outer loop cap
    outer_tol: float = 1e-6        # epsilon_0
    sum_iters: int = 32            # SUM inner loop cap
    sum_tol: float = 1e-7          # epsilon_1
    bisect_iters: int = 64         # p-root + water-filling bisections
    q_floor: float = 1e-6          # numerical floor for q in (0, 1]


# --------------------------------------------------------------------------
# Theorem 2 — CPU frequency
# --------------------------------------------------------------------------

def solve_f(params: sm.SystemParams, q: Array, queues: Array, V: float,
            k=None) -> Array:
    """(f_n^t)* = clip(cbrt(V q_n / (Q_n (1-(1-q_n)^K) alpha_n))).

    When the energy queue (or selection probability) is zero the energy
    pressure vanishes and the latency term alone drives f to f_max, which the
    clip reproduces (the unconstrained root diverges to +inf).  ``k``
    optionally replaces the static ``params.sample_count`` with a traced
    per-rollout K (the padded-K sweep paths).
    """
    sel = sm.selection_probability(q, sm.effective_k(params, k))
    denom = queues * sel * params.capacitance
    num = V * q
    cube = num / jnp.maximum(denom, _EPS)
    f_star = jnp.cbrt(cube)
    f_star = jnp.where(denom <= _EPS, params.f_max, f_star)
    return jnp.clip(f_star, params.f_min, params.f_max)


# --------------------------------------------------------------------------
# Theorem 3 — transmit power
# --------------------------------------------------------------------------

def _phi(x: Array) -> Array:
    """phi(x) = (1+x) ln(1+x) - x ; monotone increasing, phi(0) = 0."""
    return (1.0 + x) * jnp.log1p(x) - x


def solve_p(params: sm.SystemParams, q: Array, queues: Array, h: Array,
            V: float, num_iters: int = 64, k=None) -> Array:
    """Solve ``phi(x) = A_1`` for x = h p / N0 by bisection, then clip p.

    A_{1,n} = V q_n h_n / (Q_n (1-(1-q_n)^K) N0).  phi is strictly increasing
    on x >= 0, so the root is unique; Q_n -> 0 sends A_1 -> inf and the clip
    returns p_max (no energy pressure => fastest feasible upload).
    """
    sel = sm.selection_probability(q, sm.effective_k(params, k))
    denom = queues * sel * params.noise_power
    # single multiply by V: `V * q * h / ...` lets XLA's algebraic
    # simplifier reassociate the scalar-V multiply in the unbatched trace
    # but not in a vmapped one (V is then a per-lane vector), breaking the
    # ScenarioArena's lane-vs-single bitwise equality at the last ulp
    a1 = V * (q * h / jnp.maximum(denom, _EPS))
    x_max = h * params.p_max / params.noise_power

    # Bisect on [0, hi] with hi doubled until phi(hi) >= a1 (bounded by the
    # feasible box anyway: the clip below dominates once x' > x_max).
    hi0 = jnp.maximum(x_max, 1.0)

    def grow(_, hi):
        return jnp.where(_phi(hi) < a1, hi * 2.0, hi)

    hi = jax.lax.fori_loop(0, 40, grow, hi0)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        below = _phi(mid) < a1
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, num_iters, body, (lo, hi))
    x_root = 0.5 * (lo + hi)
    p_star = x_root * params.noise_power / jnp.maximum(h, _EPS)
    p_star = jnp.where(denom <= _EPS, params.p_max, p_star)
    return jnp.clip(p_star, params.p_min, params.p_max)


# --------------------------------------------------------------------------
# P2.2 — sampling probabilities via SUM + exact water-filling
# --------------------------------------------------------------------------

def _waterfill_simplex(b: Array, a3: Array, q_floor: float,
                       num_iters: int) -> Array:
    """Minimise  sum_n b_n q_n + a3_n / q_n  s.t.  sum q = 1, q in (0, 1].

    KKT: q_n(nu) = sqrt(a3_n / (b_n + nu)) clipped to (q_floor, 1];
    sum_n q_n(nu) is continuous and decreasing in nu => bisection.
    a3_n = V * lambda * w_n^2 > 0 keeps every q_n strictly positive (every
    device retains a nonzero sampling probability — the paper's (3)).
    """
    a3 = jnp.maximum(a3, _EPS)

    def q_of(nu):
        denom = jnp.maximum(b + nu, _EPS)
        return jnp.clip(jnp.sqrt(a3 / denom), q_floor, 1.0)

    # nu range: at nu_lo all q saturate at 1 (sum = N >= 1); at nu_hi the sum
    # is < 1.  sqrt(a3/(b+nu)) <= 1/N  <=  nu >= a3 N^2 - b.
    n = b.shape[0]
    nu_lo = -jnp.min(b) + _EPS
    nu_hi = jnp.max(a3 * (n ** 2) - b) + 1.0
    nu_hi = jnp.maximum(nu_hi, nu_lo + 1.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_big = jnp.sum(q_of(mid)) > 1.0  # need larger nu
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, num_iters, body, (nu_lo, nu_hi))
    q = q_of(0.5 * (lo + hi))
    # Exact simplex projection of the residual bisection error.
    return q / jnp.sum(q)


def p22_objective(params: sm.SystemParams, q: Array, t_round: Array,
                  energy: Array, queues: Array, V: float, lam: float,
                  k=None) -> Array:
    """f(q) of P2.2 (with the derived Q_n weight on the concave term)."""
    w = params.data_weights
    convex = V * jnp.sum(t_round * q + lam * jnp.square(w) / q)
    concave = -jnp.sum(queues * energy *
                       jnp.power(1.0 - q, sm.effective_k(params, k)))
    return convex + concave


def solve_q(params: sm.SystemParams, t_round: Array, energy: Array,
            queues: Array, V: float, lam: float, q_init: Array,
            cfg: SolverConfig = SolverConfig(), k=None) -> Array:
    """SUM iterations for P2.2.

    Each step linearises ``f_cve(q) = -sum Q_n E_n (1-q_n)^K`` at the current
    iterate (gradient ``Q_n E_n K (1-q_n)^{K-1}``) and exactly minimises the
    convex surrogate  sum (A2_n + c_n) q_n + A3_n / q_n  over the simplex.
    """
    w = params.data_weights
    a2 = V * t_round                    # A_{2,n}
    a3 = V * lam * jnp.square(w)        # A_{3,n}
    K = sm.effective_k(params, k)

    def cond(carry):
        q, q_prev, it = carry
        return jnp.logical_and(it < cfg.sum_iters,
                               jnp.linalg.norm(q - q_prev) > cfg.sum_tol)

    def body(carry):
        q, _, it = carry
        grad_cve = queues * energy * K * jnp.power(1.0 - q, K - 1)
        b = a2 + grad_cve
        q_new = _waterfill_simplex(b, a3, cfg.q_floor, cfg.bisect_iters)
        return q_new, q, it + 1

    q0 = q_init / jnp.sum(q_init)
    q, _, _ = jax.lax.while_loop(cond, body, (q0, q0 + 1.0, 0))
    return q


# --------------------------------------------------------------------------
# P2 — outer alternating loop (Algorithm 2)
# --------------------------------------------------------------------------

def p2_objective(params: sm.SystemParams, h: Array, decision: ControlDecision,
                 queues: Array, V: float, lam: float, k=None) -> Array:
    """V sum_n (q T + lam w^2/q) + sum_n Q_n a_n  — the P2 objective."""
    f, p, q = decision
    t = sm.round_time(params, h, p, f, k=k)
    e = sm.round_energy(params, h, p, f, k=k)
    w = params.data_weights
    penalty = V * jnp.sum(q * t + lam * jnp.square(w) / q)
    a = (sm.selection_probability(q, sm.effective_k(params, k)) * e -
        params.energy_budget)
    return penalty + jnp.sum(queues * a)


@partial(jax.jit, static_argnames=("cfg",))
def solve_p2(params: sm.SystemParams, h: Array, queues: Array,
             V: float, lam: float,
             cfg: SolverConfig = SolverConfig(), k=None) -> ControlDecision:
    """Algorithm 2: alternate (f, p) closed forms with SUM on q.

    Initial guesses follow the paper: mid-range f and p, uniform q.
    ``k`` optionally replaces the static ``params.sample_count`` with a
    traced per-rollout K everywhere Algorithm 2 reads it (the padded-K
    sweep paths, where K is per-scenario data).
    """
    n = params.num_devices
    f0 = 0.5 * (params.f_min + params.f_max)
    p0 = 0.5 * (params.p_min + params.p_max)
    q0 = jnp.full((n,), 1.0 / n, jnp.float32)

    def pack(d: ControlDecision) -> Array:
        return jnp.concatenate([d.f / params.f_max, d.p / params.p_max, d.q])

    def cond(carry):
        dec, dec_prev, it = carry
        return jnp.logical_and(
            it < cfg.outer_iters,
            jnp.linalg.norm(pack(dec) - pack(dec_prev)) > cfg.outer_tol)

    def body(carry):
        dec, _, it = carry
        f_new = solve_f(params, dec.q, queues, V, k=k)
        p_new = solve_p(params, dec.q, queues, h, V, cfg.bisect_iters, k=k)
        t = sm.round_time(params, h, p_new, f_new, k=k)
        e = sm.round_energy(params, h, p_new, f_new, k=k)
        q_new = solve_q(params, t, e, queues, V, lam, dec.q, cfg, k=k)
        return ControlDecision(f_new, p_new, q_new), dec, it + 1

    init = ControlDecision(f0, p0, q0)
    far = ControlDecision(f0 + params.f_max, p0, q0)
    dec, _, _ = jax.lax.while_loop(cond, body, (init, far, 0))
    return dec
