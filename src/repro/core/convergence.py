"""Theorem 1 — convergence bound for FedAvg with arbitrary sampling
probabilities under non-convex losses and non-IID data.

    (1/T) sum_t E||grad F(theta^t)||^2
      <= 4 (F(theta^0) - F*) / (eta T E)
       + 8 eta^2 beta^2 E^2 kappa^2
       + (2 beta eta E G^2 / (K T)) sum_t sum_n w_n^2 / q_n^t

The third term is the *sampling error* that LROA's lambda * w_n^2 / q_n
penalty controls; ``sampling_error_term`` exposes it directly so the
controller objective provably upper-bounds the optimisation error
contribution of the chosen q.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BoundConstants:
    beta: float          # smoothness (Assumption 1)
    G: float             # gradient bound (Assumption 2)
    gamma: float         # dissimilarity multiplier (Assumption 3)
    kappa: float         # dissimilarity offset (Assumption 3)
    f0_minus_fstar: float


def max_learning_rate(c: BoundConstants, local_epochs: int) -> float:
    """eta <= min{1/(32 E^2 beta^2 gamma^2), 1/(2 sqrt(2) E beta)}."""
    e = float(local_epochs)
    return min(1.0 / (32.0 * e ** 2 * c.beta ** 2 * c.gamma ** 2),
               1.0 / (2.0 * jnp.sqrt(2.0) * e * c.beta))


def sampling_error_term(w: Array, q: Array) -> Array:
    """sum_n w_n^2 / q_n — per-round sampling penalty (minimised at q = w)."""
    return jnp.sum(jnp.square(w) / q)


def convergence_bound(c: BoundConstants, eta: float, local_epochs: int,
                      sample_count: int, num_rounds: int,
                      w: Array, q_per_round: Array) -> Array:
    """Evaluate the RHS of (18). ``q_per_round``: [T, N]."""
    e = float(local_epochs)
    t = float(num_rounds)
    term1 = 4.0 * c.f0_minus_fstar / (eta * t * e)
    term2 = 8.0 * eta ** 2 * c.beta ** 2 * e ** 2 * c.kappa ** 2
    sampling = jnp.sum(jnp.square(w)[None, :] / q_per_round) / t
    term3 = (2.0 * c.beta * eta * e * c.G ** 2 / sample_count) * sampling
    return term1 + term2 + term3
