"""LROA controller — the paper's online control policy as a reusable object.

Per round:  observe channel gains ``h^t``  ->  ``decide`` (Algorithm 2 /
``solver.solve_p2``)  ->  run the FL round  ->  ``step_queues``.

Hyper-parameter initialisation follows Sec. VII-B:

  lambda_0 = T_0 / F_0     with T_0 the mid-range per-round latency estimate
                           and F_0 a loss-scale estimate (q = w),
  V_0      = a_0^2 / (T_0 + lambda * F_0)   with a_0 the energy-residual
                           estimate from eq. (20) at the mid-range operating
                           point (Q_0 = a_0),
  lambda = mu * lambda_0,  V = nu * V_0.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core import queues as vq
from repro.core import solver as slv
from repro.core import system_model as sm

Array = jax.Array


@dataclasses.dataclass
class LROAHyperParams:
    lam: float
    V: float
    lam0: float
    V0: float
    mu: float
    nu: float


def estimate_hyperparams_arrays(params: sm.SystemParams, mean_gain,
                                loss_scale=1.0, mu=1.0, nu=1e5
                                ) -> Tuple[Array, Array, Array, Array]:
    """Pure-jax Sec. VII-B estimates: ``(lam, V, lam0, V0)`` as jnp scalars.

    Every input past ``params`` may be a traced scalar, so the whole
    estimate jits and ``vmap``s — the ScenarioArena derives per-scenario
    hyperparameters from (mean_gain, mu, nu) grids inside its setup jit
    (the old implementation round-tripped ``t0``/``a0`` through host
    ``float()``s, which broke under trace).
    """
    f_mid = 0.5 * (params.f_min + params.f_max)
    p_mid = 0.5 * (params.p_min + params.p_max)
    h = jnp.broadcast_to(jnp.asarray(mean_gain, jnp.float32),
                         (params.num_devices,))
    t0 = jnp.sum(params.data_weights *
                 sm.round_time(params, h, p_mid, f_mid))
    f0 = jnp.asarray(loss_scale, jnp.float32)
    lam0 = t0 / jnp.maximum(f0, 1e-12)
    lam = mu * lam0
    q_w = params.data_weights
    e0 = sm.round_energy(params, h, p_mid, f_mid)
    a0 = jnp.mean(jnp.abs(
        sm.selection_probability(q_w, params.sample_count) * e0
        - params.energy_budget))
    v0 = jnp.square(a0) / jnp.maximum(t0 + lam * f0, 1e-12)
    return lam, nu * v0, lam0, v0


def estimate_hyperparams(params: sm.SystemParams, mean_gain: float,
                         loss_scale: float = 1.0, mu: float = 1.0,
                         nu: float = 1e5) -> LROAHyperParams:
    """lambda_0 = T_0/F_0 and V_0 = a_0^2/(T_0 + lambda F_0) (Sec. VII-B)."""
    lam, v, lam0, v0 = estimate_hyperparams_arrays(
        params, mean_gain, loss_scale=loss_scale, mu=mu, nu=nu)
    return LROAHyperParams(lam=float(lam), V=float(v), lam0=float(lam0),
                           V0=float(v0), mu=mu, nu=nu)


class LROAController:
    """Stateful wrapper: virtual queues + Algorithm 2 decisions.

    The decision rule itself is the pure :func:`repro.core.policy.
    decide_lroa` — this class only carries the queue state and
    hyper-parameters for the host-driven loop, so the fused rollout
    paths (``run_scan`` / ScenarioArena) share the identical rule.
    """

    name = "lroa"

    def __init__(self, params: sm.SystemParams, hp: LROAHyperParams,
                 cfg: slv.SolverConfig = slv.SolverConfig()):
        self.params = params
        self.hp = hp
        self.cfg = cfg
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        return pol.decide_lroa(self.params, h, self.queues,
                               self.hp.V, self.hp.lam, self.cfg)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues

    def round_stats(self, h: Array, decision: slv.ControlDecision) -> dict:
        f, p, q = decision
        t = sm.round_time(self.params, h, p, f)
        e = sm.expected_energy(self.params, h, p, f, q)
        w = self.params.data_weights
        obj = float(jnp.sum(q * t + self.hp.lam * jnp.square(w) / q))
        stats = dict(
            expected_latency=float(sm.expected_round_latency(q, t)),
            objective=obj,
            expected_energy=float(jnp.mean(e)),
            queue_mean=float(jnp.mean(self.queues)),
            queue_max=float(jnp.max(self.queues)),
        )
        self.history.append(stats)
        return stats


def realized_round_time(params: sm.SystemParams, h: Array,
                        decision: slv.ControlDecision,
                        selected: np.ndarray) -> float:
    """Wall-clock time of a round = max over the realised selected set (10)."""
    t = sm.round_time(params, h, decision.p, decision.f)
    uniq = np.unique(np.asarray(selected))
    return float(jnp.max(jnp.asarray(t)[uniq]))


def realized_energy(params: sm.SystemParams, h: Array,
                    decision: slv.ControlDecision,
                    selected: np.ndarray) -> np.ndarray:
    """Per-device energy actually drawn this round (selected devices only)."""
    e = np.asarray(sm.round_energy(params, h, decision.p, decision.f))
    out = np.zeros_like(e)
    uniq = np.unique(np.asarray(selected))
    out[uniq] = e[uniq]
    return out
