"""LROA controller — the paper's online control policy as a reusable object.

Per round:  observe channel gains ``h^t``  ->  ``decide`` (Algorithm 2 /
``solver.solve_p2``)  ->  run the FL round  ->  ``step_queues``.

Hyper-parameter initialisation follows Sec. VII-B:

  lambda_0 = T_0 / F_0     with T_0 the mid-range per-round latency estimate
                           and F_0 a loss-scale estimate (q = w),
  V_0      = a_0^2 / (T_0 + lambda * F_0)   with a_0 the energy-residual
                           estimate from eq. (20) at the mid-range operating
                           point (Q_0 = a_0),
  lambda = mu * lambda_0,  V = nu * V_0.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queues as vq
from repro.core import solver as slv
from repro.core import system_model as sm

Array = jax.Array


@dataclasses.dataclass
class LROAHyperParams:
    lam: float
    V: float
    lam0: float
    V0: float
    mu: float
    nu: float


def estimate_hyperparams(params: sm.SystemParams, mean_gain: float,
                         loss_scale: float = 1.0, mu: float = 1.0,
                         nu: float = 1e5) -> LROAHyperParams:
    """lambda_0 = T_0/F_0 and V_0 = a_0^2/(T_0 + lambda F_0) (Sec. VII-B)."""
    f_mid = 0.5 * (params.f_min + params.f_max)
    p_mid = 0.5 * (params.p_min + params.p_max)
    h = jnp.full((params.num_devices,), mean_gain, jnp.float32)
    t0 = float(jnp.sum(params.data_weights *
                       sm.round_time(params, h, p_mid, f_mid)))
    f0 = float(loss_scale)
    lam0 = t0 / max(f0, 1e-12)
    lam = mu * lam0
    q_w = params.data_weights
    e0 = sm.round_energy(params, h, p_mid, f_mid)
    a0 = float(jnp.mean(jnp.abs(
        sm.selection_probability(q_w, params.sample_count) * e0
        - params.energy_budget)))
    v0 = a0 ** 2 / max(t0 + lam * f0, 1e-12)
    return LROAHyperParams(lam=lam, V=nu * v0, lam0=lam0, V0=v0, mu=mu, nu=nu)


class LROAController:
    """Stateful wrapper: virtual queues + Algorithm 2 decisions."""

    name = "lroa"

    def __init__(self, params: sm.SystemParams, hp: LROAHyperParams,
                 cfg: slv.SolverConfig = slv.SolverConfig()):
        self.params = params
        self.hp = hp
        self.cfg = cfg
        self.queues = vq.init_queues(params.num_devices)
        self.history: list[dict] = []

    def decide(self, h: Array) -> slv.ControlDecision:
        return slv.solve_p2(self.params, h, self.queues,
                            self.hp.V, self.hp.lam, self.cfg)

    def step_queues(self, h: Array, decision: slv.ControlDecision) -> Array:
        inc = vq.energy_increment(self.params, h, decision.p, decision.f,
                                  decision.q)
        self.queues = vq.update_queues(self.queues, inc)
        return self.queues

    def round_stats(self, h: Array, decision: slv.ControlDecision) -> dict:
        f, p, q = decision
        t = sm.round_time(self.params, h, p, f)
        e = sm.expected_energy(self.params, h, p, f, q)
        w = self.params.data_weights
        obj = float(jnp.sum(q * t + self.hp.lam * jnp.square(w) / q))
        stats = dict(
            expected_latency=float(sm.expected_round_latency(q, t)),
            objective=obj,
            expected_energy=float(jnp.mean(e)),
            queue_mean=float(jnp.mean(self.queues)),
            queue_max=float(jnp.max(self.queues)),
        )
        self.history.append(stats)
        return stats


def realized_round_time(params: sm.SystemParams, h: Array,
                        decision: slv.ControlDecision,
                        selected: np.ndarray) -> float:
    """Wall-clock time of a round = max over the realised selected set (10)."""
    t = sm.round_time(params, h, decision.p, decision.f)
    uniq = np.unique(np.asarray(selected))
    return float(jnp.max(jnp.asarray(t)[uniq]))


def realized_energy(params: sm.SystemParams, h: Array,
                    decision: slv.ControlDecision,
                    selected: np.ndarray) -> np.ndarray:
    """Per-device energy actually drawn this round (selected devices only)."""
    e = np.asarray(sm.round_energy(params, h, decision.p, decision.f))
    out = np.zeros_like(e)
    uniq = np.unique(np.asarray(selected))
    out[uniq] = e[uniq]
    return out
