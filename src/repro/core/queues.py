"""Virtual energy-consumption queues (paper Sec. VI-A, eqs. (19)-(21)).

Queue stability <=> satisfaction of the long-term average energy constraint
(16); the quadratic Lyapunov function and one-slot drift are provided for
diagnostics and for the Lemma-1 constant ``C``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import system_model as sm

Array = jax.Array


def init_queues(num_devices: int) -> Array:
    """Q^0 = 0."""
    return jnp.zeros((num_devices,), jnp.float32)


def energy_increment(params: sm.SystemParams, h: Array, p: Array, f: Array,
                     q: Array, k=None) -> Array:
    """a_n^t = (1-(1-q)^K) E_n^t - Ebar_n — eq. (20).

    ``k`` optionally replaces the static ``params.sample_count`` with a
    traced per-rollout K (the padded-K sweep paths).
    """
    return (sm.expected_energy(params, h, p, f, q, k=k) -
            params.energy_budget)


def update_queues(queues: Array, increment: Array) -> Array:
    """Q^{t+1} = max(Q^t + a^t, 0) — eq. (19)."""
    return jnp.maximum(queues + increment, 0.0)


def lyapunov(queues: Array) -> Array:
    """L(t) = 1/2 sum_n Q_n^2 — eq. (21)."""
    return 0.5 * jnp.sum(jnp.square(queues))


def drift(queues_next: Array, queues: Array) -> Array:
    """One-slot Lyapunov drift L(t+1) - L(t) — realisation of eq. (22)."""
    return lyapunov(queues_next) - lyapunov(queues)


def lemma1_constant(params: sm.SystemParams, t_com_upper: Array) -> Array:
    """The constant C of Lemma 1 (with Tbar the upload-time upper bound).

    C = sum_n [ (Tbar p_max + E alpha c D f_max^2 / 2)^2 + Ebar^2 ].
    """
    e_cmp_max = (0.5 * params.local_epochs * params.capacitance *
                 params.cycles_per_sample * params.data_sizes *
                 jnp.square(params.f_max))
    term = jnp.square(t_com_upper * params.p_max + e_cmp_max)
    return jnp.sum(term + jnp.square(params.energy_budget))
