#!/usr/bin/env python
"""Doc drift guard: README/docs links must resolve, and every
``python -m benchmarks.run ...`` command quoted in the docs must parse
against the real benchmark CLI (``benchmarks.run.build_parser``).

Dependency-free (stdlib only — ``benchmarks.run`` imports nothing heavy
at module level) so CI can run it without installing the jax toolchain:

    python tools/check_docs.py

Exits non-zero with one line per problem.
"""

from __future__ import annotations

import re
import shlex
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images' alt brackets is unnecessary; the
# target grammar is the same.  External/anchor links are skipped.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CMD = re.compile(r"python -m benchmarks\.run[^\n`]*")


def doc_files() -> list[Path]:
    docs = [REPO / "README.md"]
    docs += sorted((REPO / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def check_links(path: Path) -> list[str]:
    problems = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            problems.append(f"{path.relative_to(REPO)}: broken link "
                            f"-> {target}")
    return problems


def check_bench_commands(path: Path) -> list[str]:
    sys.path.insert(0, str(REPO))
    from benchmarks.run import build_parser
    problems = []
    for cmd in _CMD.findall(path.read_text()):
        argv = shlex.split(cmd)[3:]          # drop "python -m benchmarks.run"
        try:
            build_parser().parse_args(argv)
        except SystemExit:
            problems.append(f"{path.relative_to(REPO)}: command does not "
                            f"parse -> {cmd!r}")
    return problems


def main() -> int:
    problems = []
    files = doc_files()
    required = {"README.md", "docs/architecture.md", "docs/reproducing.md"}
    present = {str(f.relative_to(REPO)) for f in files}
    for missing in sorted(required - present):
        problems.append(f"missing required doc: {missing}")
    n_cmds = 0
    for f in files:
        problems += check_links(f)
        if f.name == "reproducing.md":
            cmds = check_bench_commands(f)
            n_cmds = len(_CMD.findall(f.read_text()))
            problems += cmds
    if n_cmds == 0 and "docs/reproducing.md" in present:
        problems.append("docs/reproducing.md quotes no benchmarks.run "
                        "commands — the drift guard has nothing to guard")
    for p in problems:
        print(f"check_docs: {p}", file=sys.stderr)
    if not problems:
        print(f"check_docs: OK ({len(files)} docs, {n_cmds} bench "
              f"commands verified)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
