#!/usr/bin/env python
"""Render a flight-recorder JSONL span log into a per-phase breakdown.

The observability layer (``repro.obs``) writes one JSON object per
completed span to ``runlogs/<run>.jsonl`` (``trace.JsonlSink``).  This
tool turns that log into the operator's view:

* **Per-phase breakdown** — total / mean / p50 / p99 wall time and call
  count per span name (``arena.plan`` / ``arena.compile`` /
  ``arena.upload`` / ``arena.dispatch`` / ``arena.reduce`` / ...),
  sorted by total time, plus each phase's share of the run's traced
  wall clock.
* **Health summary** — watchdog violations (``watchdog.retrace``
  events) with their cache-key diffs, compile activity after the first
  ``arena.run``, and the dispatch/reduce stall ratio (p99 / p50) of the
  streaming path.
* **Chrome-trace export** (``--chrome out.json``) — the same records as
  a ``chrome://tracing`` / Perfetto-loadable ``traceEvents`` file.

Usage::

    python tools/obs_report.py runlogs/sweep.jsonl
    python tools/obs_report.py runlogs/sweep.jsonl --chrome trace.json
    python tools/obs_report.py runlogs/sweep.jsonl --json   # raw dict
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import trace  # noqa: E402


def _percentile(vals: List[float], q: float) -> float:
    """Nearest-rank percentile (matches ``repro.obs.metrics``)."""
    if not vals:
        return math.nan
    vals = sorted(vals)
    rank = max(0, min(len(vals) - 1,
                      int(math.ceil(q / 100.0 * len(vals))) - 1))
    return vals[rank]


def phase_breakdown(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Aggregate span records into one row per span name."""
    by_name: Dict[str, List[float]] = {}
    for r in records:
        if r.get("dur", 0.0) > 0.0:
            by_name.setdefault(r["name"], []).append(float(r["dur"]))
    total_all = sum(sum(v) for v in by_name.values()) or math.nan
    rows = []
    for name, durs in by_name.items():
        total = sum(durs)
        rows.append({
            "phase": name, "count": len(durs), "total_s": total,
            "share": total / total_all, "mean_s": total / len(durs),
            "p50_s": _percentile(durs, 50.0),
            "p99_s": _percentile(durs, 99.0),
        })
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def health_summary(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """The run's contract checks: retrace violations, post-first-run
    compiles, and streaming stall ratios."""
    violations = [r for r in records if r["name"] == "watchdog.retrace"]
    runs = [r for r in records if r["name"] == "arena.run"]
    # a compile is "late" only when it starts after the FIRST run has
    # finished (ts is span start — compiles inside the cold first run
    # are expected; steady state must be compile-free)
    first_run_end = min((r["ts"] + r.get("dur", 0.0) for r in runs),
                        default=None)
    late_compiles = [
        r for r in records
        if r["name"] == "arena.compile" and first_run_end is not None
        and r["ts"] > first_run_end]
    out: Dict[str, Any] = {
        "spans": len(records),
        "runs": len(runs),
        "watchdog_violations": [r.get("attrs", {}) for r in violations],
        "compiles_after_first_run": len(late_compiles),
    }
    for phase in ("arena.dispatch", "arena.reduce"):
        durs = [float(r["dur"]) for r in records
                if r["name"] == phase and r.get("dur", 0.0) > 0.0]
        if durs:
            p50, p99 = _percentile(durs, 50.0), _percentile(durs, 99.0)
            out[phase.split(".")[1] + "_stall_ratio"] = (
                p99 / p50 if p50 > 0 else math.nan)
    return out


def render(records: List[Dict[str, Any]]) -> str:
    rows = phase_breakdown(records)
    health = health_summary(records)
    lines = ["== per-phase breakdown ==",
             f"{'phase':<18} {'count':>6} {'total_s':>9} {'share':>6} "
             f"{'mean_s':>9} {'p50_s':>9} {'p99_s':>9}"]
    for r in rows:
        lines.append(
            f"{r['phase']:<18} {r['count']:>6} {r['total_s']:>9.4f} "
            f"{r['share']:>5.0%} {r['mean_s']:>9.5f} {r['p50_s']:>9.5f} "
            f"{r['p99_s']:>9.5f}")
    lines.append("")
    lines.append("== health ==")
    lines.append(f"spans recorded        : {health['spans']}")
    lines.append(f"arena runs            : {health['runs']}")
    lines.append(f"compiles after 1st run: "
                 f"{health['compiles_after_first_run']}")
    nviol = len(health["watchdog_violations"])
    lines.append(f"watchdog violations   : {nviol}"
                 + ("  OK" if nviol == 0 else "  <-- RETRACE"))
    for v in health["watchdog_violations"]:
        lines.append(f"  - retraces={v.get('retraces')} "
                     f"new_executables={v.get('new_executables')}")
    for key in ("dispatch_stall_ratio", "reduce_stall_ratio"):
        if key in health:
            lines.append(f"{key:<22}: {health[key]:.2f}  (p99/p50)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("log", help="flight-recorder JSONL file (JsonlSink)")
    ap.add_argument("--chrome", metavar="OUT",
                    help="additionally export a Chrome-trace/Perfetto "
                         "JSON file")
    ap.add_argument("--json", action="store_true",
                    help="print the breakdown + health as JSON instead "
                         "of the table")
    args = ap.parse_args(argv)
    records = trace.load_jsonl(args.log)
    if not records:
        print(f"no span records in {args.log}", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps({"phases": phase_breakdown(records),
                          "health": health_summary(records)}, indent=2))
    else:
        print(render(records))
    if args.chrome:
        path = trace.export_chrome_trace(records, args.chrome)
        print(f"\nchrome trace written to {path} "
              f"(open in chrome://tracing or ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
