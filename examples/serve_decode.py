"""Batched serving demo: prefill a prompt batch, then greedy-decode with the
one-token ``serve_step`` (KV caches, ring-buffer window caches on local
layers, flash-decode on long global caches).

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-27b]
        (the smoke variant of the arch is served on CPU)
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import synthetic_lm_tokens
from repro.launch.steps import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = args.prompt_len + args.new_tokens

    prompts = jnp.asarray(synthetic_lm_tokens(
        args.batch, args.prompt_len, cfg.vocab_size, seed=1))
    print(f"serving {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} +{args.new_tokens} tokens")

    # prefill -> per-layer caches; pad global caches to the full horizon
    logits, _, cache = jax.jit(
        lambda p, t: model.apply(p, t, mode="prefill"))(params, prompts)
    ref_cache = model.init_cache(args.batch, total)
    cache = jax.tree_util.tree_map(
        lambda cp, cf: jnp.pad(cp, [(0, cf.shape[i] - cp.shape[i])
                                    for i in range(cp.ndim)]),
        cache, ref_cache)

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    generated = [tok]
    for i in range(args.new_tokens - 1):
        idx = jnp.asarray(args.prompt_len + i, jnp.int32)
        lg, cache = decode(params, cache, tok, idx)
        tok = jnp.argmax(lg[:, -1, :], axis=-1)[:, None]
        generated.append(tok)

    out = jnp.concatenate(generated, axis=1)
    for b in range(args.batch):
        print(f"  prompt[{b}] {np.asarray(prompts[b])[:8]}... -> "
              f"generated {np.asarray(out[b])}")
    print("decode loop OK (ring-buffer local caches + full global caches).")


if __name__ == "__main__":
    main()
