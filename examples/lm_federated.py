"""Federated training of a language model with the SPMD client-parallel
round step (``make_fl_round_step``): K clients run local SGD **inside one
jitted program** (clients vmapped — the axis that shards over the mesh's
``data`` axis at scale) and the unbiased aggregation (paper eq. 4) reduces
their deltas. LROA supplies the per-round sampling probabilities/coeffs.

    PYTHONPATH=src python examples/lm_federated.py [--rounds 20]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (LROAController, estimate_hyperparams,
                        paper_default_params)
from repro.data import synthetic_lm_tokens
from repro.fl import ChannelConfig, ChannelProcess, sample_clients
from repro.fl.server import aggregation_weights
from repro.launch.steps import build_model, make_fl_round_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--arch", default="gemma-2b",
                    help="smoke variant of this arch is trained")
    args = ap.parse_args()

    n, k = args.devices, 2
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name} ({d/1e6:.2f}M params)")

    # per-client token shards (zipf-bigram synthetic corpus)
    rng = np.random.default_rng(0)
    shards = [synthetic_lm_tokens(8, 33, cfg.vocab_size, seed=i)
              for i in range(n)]
    sizes = np.asarray([s.size for s in shards], np.float32)

    sys_params = paper_default_params(num_devices=n, data_sizes=sizes,
                                      model_params=d)
    hp = estimate_hyperparams(sys_params, 0.1, loss_scale=5.0)
    controller = LROAController(sys_params, hp)
    channel = ChannelProcess(n, ChannelConfig(seed=0))
    w = np.asarray(sys_params.data_weights)

    round_step = jax.jit(make_fl_round_step(cfg, k, lr=0.3, local_steps=4))

    for t in range(args.rounds):
        h = jnp.asarray(channel.sample())
        dec = controller.decide(h)
        selected = sample_clients(rng, np.asarray(dec.q), k)
        coeffs = aggregation_weights(selected, np.asarray(dec.q), w, k)
        toks = np.stack([shards[i] for i in selected])    # [K, B, S+1]
        batch = {"tokens": jnp.asarray(toks[:, :, :-1]),
                 "labels": jnp.asarray(toks[:, :, 1:]),
                 "coeffs": jnp.asarray(coeffs)}
        params, metrics = round_step(params, batch)
        controller.step_queues(h, dec)
        print(f"round {t:3d}  clients {selected.tolist()}  "
              f"loss {float(metrics['loss']):.4f}")

    print("\nfederated LM training ran end-to-end (client-parallel SPMD "
          "round step + eq.-(4) aggregation).")


if __name__ == "__main__":
    main()
