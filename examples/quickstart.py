"""Quickstart: LROA online control in ~40 lines.

Builds the paper's edge system (heterogeneous devices, random channels),
runs Algorithm 2 each round, and shows the Lyapunov trade-off: latency is
minimised while the per-device energy queues stay bounded (energy budget
satisfied on time-average).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (LROAController, estimate_hyperparams,
                        paper_default_params)
from repro.core import system_model as sm
from repro.fl import ChannelConfig, ChannelProcess

N_DEVICES, ROUNDS = 40, 400

rng = np.random.default_rng(0)
params = paper_default_params(
    num_devices=N_DEVICES,
    data_sizes=rng.integers(200, 600, N_DEVICES).astype(np.float32))
# nu trades objective quality for constraint-convergence speed (Thm 4 /
# Fig. 4); a small nu makes the energy queues bite within this short demo.
hp = estimate_hyperparams(params, mean_gain=0.1, loss_scale=1.5,
                          mu=1.0, nu=1e3)
print(f"lambda = {hp.lam:.1f}  V = {hp.V:.3g}")

controller = LROAController(params, hp)
channel = ChannelProcess(N_DEVICES, ChannelConfig(seed=0))

energy = np.zeros(N_DEVICES)
for t in range(ROUNDS):
    h = jnp.asarray(channel.sample())           # observe channels (Alg.1 l.3)
    decision = controller.decide(h)             # Algorithm 2 (f, p, q)
    energy += np.asarray(sm.expected_energy(params, h, decision.p,
                                            decision.f, decision.q))
    controller.step_queues(h, decision)         # queue update (eq. 19)
    if t % 80 == 0 or t == ROUNDS - 1:
        lat = float(sm.expected_round_latency(
            decision.q, sm.round_time(params, h, decision.p, decision.f)))
        print(f"round {t:4d}  E[latency] {lat:8.1f}s  "
              f"q in [{float(decision.q.min()):.4f}, "
              f"{float(decision.q.max()):.4f}]  "
              f"queue max {float(controller.queues.max()):9.1f}  "
              f"avg energy {energy.mean() / (t + 1):6.2f} J "
              f"(budget {float(np.asarray(params.energy_budget)[0]):.0f} J)")

print("\nDone: sampling probabilities now favour fast/cheap devices while "
      "the time-average energy approaches the budget.")
