"""End-to-end FL driver (the paper's experiment, Figs. 1/2): train a CNN
with LROA and the baselines over a non-IID synthetic image dataset (offline
stand-in for CIFAR-10/FEMNIST — same Dirichlet(0.5) partition, same system
model), then print the accuracy/latency comparison.

The controller comparison grid (the full zoo — LROA, Uni-D, Uni-S,
channel-aware, cost-effective, round-robin, DivFL — any number of seeds)
runs through the ScenarioArena: ONE jitted, scenario-batched program
executes every rollout over the shared ClientBank instead of a Python
loop of trainers.  DivFL's facility-location greedy runs in-trace, so it
is an ordinary arena lane like everything else.

    PYTHONPATH=src python examples/fl_simulation.py [--rounds 60] \
        [--devices 30] [--controllers lroa,uni_d,uni_s,divfl] [--seeds 3]
"""

import argparse

import jax
import numpy as np

from benchmarks.common import BenchConfig, build_testbed
from repro.core import estimate_hyperparams
from repro.fl import ClientConfig, RoundEngine
from repro.optim import paper_step_decay
from repro.sim import Arena, EvalBank, ScenarioGrid


def run_arena_grid(names, cfg: BenchConfig, num_seeds: int):
    """All scan-traceable controllers x seeds as one batched arena run;
    returns {controller: (mean final accuracy, mean total latency)}.

    Accuracy comes from the arena's on-device batched evaluation (an
    ``EvalBank`` holding the test set, evaluated for every lane in one
    vmapped dispatch) — the old host-side per-lane ``task.metrics`` loop
    is gone."""
    params, task, client_data, (xte, yte) = build_testbed(cfg)
    hp = estimate_hyperparams(params, 0.1, loss_scale=1.5, mu=cfg.mu,
                              nu=cfg.nu)
    engine = RoundEngine(task, ClientConfig(local_epochs=cfg.local_epochs,
                                            batch_size=cfg.batch_size))
    bank = engine.make_bank(client_data)
    eval_bank = EvalBank(task, xte, yte)
    grid = ScenarioGrid.product(controllers=names,
                                seeds=np.arange(num_seeds) + cfg.seed,
                                V=(hp.V,), lam=(hp.lam,),
                                sample_count=(cfg.sample_count,),
                                num_devices=cfg.num_devices)
    arena = Arena(engine)
    sched = paper_step_decay(cfg.lr, cfg.rounds)
    lr_seq = np.asarray([float(sched(t)) for t in range(cfg.rounds)],
                        np.float32)
    report = arena.run(task.init(jax.random.PRNGKey(cfg.seed + 1)), params,
                       bank, grid, cfg.rounds, lr_seq,
                       eval_bank=eval_bank)
    total = report.total_latency()
    accuracy = report.final_accuracy()
    results = {}
    for name in grid.controller_names():
        results.setdefault(name, ([], []))
    for s, name in enumerate(grid.controller_names()):
        results[name][0].append(float(accuracy[s]))
        results[name][1].append(float(total[s]))
    return {name: (float(np.mean(accs)), float(np.mean(times)))
            for name, (accs, times) in results.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--devices", type=int, default=24)
    ap.add_argument("--controllers", default="lroa,uni_d,uni_s")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per controller (arena lanes = "
                         "controllers x seeds)")
    ap.add_argument("--cnn", action="store_true",
                    help="use the CNN task (slower, closer to the paper)")
    ap.add_argument("--obs", metavar="LOG", nargs="?",
                    const="runlogs/fl_simulation.jsonl", default=None,
                    help="record a flight-recorder span log (JSONL); "
                         "render with tools/obs_report.py")
    args = ap.parse_args()

    sink = None
    if args.obs:
        from repro.obs import trace as obs_trace
        sink = obs_trace.install_sink(obs_trace.JsonlSink(args.obs))

    cfg = BenchConfig(num_devices=args.devices, rounds=args.rounds,
                      use_cnn=args.cnn)
    names = args.controllers.split(",")
    s = len(names) * args.seeds
    print(f"=== arena: {','.join(names)} x {args.seeds} "
          f"seed(s) = {s} rollouts in one batched program ===")
    results = run_arena_grid(names, cfg, args.seeds)

    print(f"\n{'controller':10s} {'final acc':>10s} {'total time':>12s}")
    for name, (acc, total) in results.items():
        print(f"{name:10s} {acc:10.3f} {total:11.0f}s")
    if "lroa" in results:
        for base, (_, total) in results.items():
            if base == "lroa":
                continue
            save = 100 * (1 - results["lroa"][1] / total)
            print(f"LROA saves {save:.1f}% total latency vs {base}")

    if sink is not None:
        from repro.obs import trace as obs_trace
        obs_trace.remove_sink(sink)
        sink.close()
        print(f"\nobs: span log written to {sink.path} — render with "
              f"'python tools/obs_report.py {sink.path}'")


if __name__ == "__main__":
    main()
