"""End-to-end FL driver (the paper's experiment, Figs. 1/2): train a CNN
with LROA and the baselines over a non-IID synthetic image dataset (offline
stand-in for CIFAR-10/FEMNIST — same Dirichlet(0.5) partition, same system
model), then print the accuracy/latency comparison.

    PYTHONPATH=src python examples/fl_simulation.py [--rounds 60] \
        [--devices 30] [--controllers lroa,uni_d,uni_s,divfl]
"""

import argparse

from benchmarks.common import BenchConfig, run_controller


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--devices", type=int, default=24)
    ap.add_argument("--controllers", default="lroa,uni_d,uni_s")
    ap.add_argument("--cnn", action="store_true",
                    help="use the CNN task (slower, closer to the paper)")
    args = ap.parse_args()

    cfg = BenchConfig(num_devices=args.devices, rounds=args.rounds,
                      use_cnn=args.cnn)
    results = {}
    for name in args.controllers.split(","):
        print(f"=== {name} ===")
        results[name] = run_controller(name, cfg, verbose=True)

    print(f"\n{'controller':10s} {'final acc':>10s} {'total time':>12s}")
    for name, res in results.items():
        acc = res.accuracy_curve()[-1][2]
        print(f"{name:10s} {acc:10.3f} {res.total_time:11.0f}s")
    if "lroa" in results:
        for base, res in results.items():
            if base == "lroa":
                continue
            save = 100 * (1 - results["lroa"].total_time / res.total_time)
            print(f"LROA saves {save:.1f}% total latency vs {base}")


if __name__ == "__main__":
    main()
